"""Benchmarks reproducing each paper table/figure (SVI), scaled to one core.

Every function prints ``name,us_per_call,derived`` CSV rows (benchmarks.run
is the driver).  The ``derived`` column carries the figure's metric
(observed error / seconds / items-per-second), so EXPERIMENTS.md quotes
these rows directly.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    emit,
    ipv4_like,
    sketch_error,
    standard_specs,
    timed,
    twitter_like,
)
from repro.core import sketch as sk
from repro.core.exhaustive import exhaustive_config
from repro.core.fcm import FCM, fcm_spec, fmod_spec
from repro.core.greedy import greedy_config
from repro.core.partition import bell_number
from repro.core.range_opt import estimate_alpha, optimal_ranges_mod2, split_range
from repro.streams import reinterpret_modularity

KEY = jax.random.PRNGKey(0)


def table1_bell() -> None:
    """Table I: T(n) vs 2^n."""
    t0 = time.perf_counter()
    vals = {n: bell_number(n) for n in range(1, 12)}
    us = (time.perf_counter() - t0) * 1e6
    expect = {1: 1, 2: 2, 3: 5, 4: 15, 5: 52, 6: 203, 7: 877, 8: 4140,
              9: 21147, 10: 115975, 11: 678570}
    ok = vals == expect
    emit("table1_bell", us, f"match_paper={ok};T8={vals[8]};T11={vals[11]}")


def fig4_accuracy_vs_k() -> None:
    """Fig 4: observed error vs k (top-k and random-k), modularity 2."""
    for stream in (twitter_like(), ipv4_like(1)):
        h, w = 4096, 5
        t0 = time.perf_counter()
        specs = standard_specs(stream, h, w)
        us = (time.perf_counter() - t0) * 1e6
        rng = np.random.default_rng(0)
        for k in (100, 1000):
            for qname, queries in (
                ("top", stream.top_k_queries(k)),
                ("rand", stream.random_k_queries(k, rng)),
            ):
                errs = {n: sketch_error(s, stream, KEY, queries)
                        for n, s in specs.items()}
                best = min(errs, key=errs.get)
                emit(f"fig4_{stream.name}_{qname}{k}", us,
                     ";".join(f"{n}={e:.4f}" for n, e in errs.items())
                     + f";best={best}")


def fig5_sample_size() -> None:
    """Fig 5: MOD error converges by ~2% sample."""
    stream = twitter_like()
    h, w = 4096, 5
    queries = stream.top_k_queries(500)
    for frac in (0.005, 0.01, 0.02, 0.04):
        rng = np.random.default_rng(1)
        t0 = time.perf_counter()
        s_items, s_freqs = stream.sample(frac, rng)
        a, b = optimal_ranges_mod2(s_items, s_freqs, h)
        us = (time.perf_counter() - t0) * 1e6
        err = sketch_error(sk.mod_sketch_spec(stream.schema, [(0,), (1,)],
                                              (a, b), w), stream, KEY, queries)
        emit(f"fig5_sample{frac}", us, f"err={err:.4f};a={a};b={b}")


def fig6_param_search_time() -> None:
    """Fig 6: time to find parameters, MOD vs Exhaustive (mod 2)."""
    stream = twitter_like()
    rng = np.random.default_rng(2)
    s_items, s_freqs = stream.sample(0.02, rng)
    h, w = 4096, 5
    t0 = time.perf_counter()
    a, b = optimal_ranges_mod2(s_items, s_freqs, h)
    t_mod = time.perf_counter() - t0
    t0 = time.perf_counter()
    ex = exhaustive_config(s_items, s_freqs, stream.schema, h, w, KEY, grid=9)
    t_ex = time.perf_counter() - t0
    emit("fig6_param_time", t_mod * 1e6,
         f"mod_s={t_mod:.2f};exhaustive_s={t_ex:.2f};"
         f"speedup={t_ex / max(t_mod, 1e-9):.1f}x;"
         f"mod_ab=({a},{b});ex={'x'.join(map(str, ex.spec.ranges))}")


def fig7_modularity_4_8() -> None:
    """Fig 7: error at modularity 4/8 with varying w."""
    base = ipv4_like(1)
    for mod in (4, 8):
        stream = reinterpret_modularity(base, mod)
        rng = np.random.default_rng(3)
        s_items, s_freqs = stream.sample(0.03, rng)
        h = 4096
        queries = stream.top_k_queries(300)
        for w in (3, 5):
            t0 = time.perf_counter()
            g = greedy_config(s_items, s_freqs, stream.schema, h, w, KEY)
            us = (time.perf_counter() - t0) * 1e6
            errs = {
                "count-min": sketch_error(
                    sk.count_min_spec(stream.schema, h, w), stream, KEY, queries),
                "equal-sketch": sketch_error(
                    sk.equal_sketch_spec(stream.schema, h, w), stream, KEY, queries),
                "mod-sketch": sketch_error(g.spec, stream, KEY, queries),
            }
            emit(f"fig7_mod{mod}_w{w}", us,
                 ";".join(f"{n}={e:.4f}" for n, e in errs.items())
                 + f";greedy_cfg={g.spec.describe()}")


def fig8_throughput() -> None:
    """Fig 8: stream update throughput (items/s), h = 4e6 class."""
    stream = twitter_like()
    h, w = 1 << 20, 5
    n = min(200_000, len(stream.items))
    items = jnp.asarray(stream.items[:n])
    freqs = jnp.asarray(stream.freqs[:n].astype(np.int32))
    for name, spec in standard_specs(stream, h, w).items():
        holder = {"state": sk.init_state(spec, KEY)}

        def step():
            # thread the state through: update_jit donates the table, so
            # each timed call must fold into the previous call's result
            # (the streaming-ingest shape this figure measures anyway)
            holder["state"] = sk.update_jit(spec, holder["state"], items,
                                            freqs)
            jax.block_until_ready(holder["state"].table)
            return holder["state"]

        us, _ = timed(step)
        emit(f"fig8_throughput_{name}", us,
             f"items_per_s={n / (us / 1e6):.3e}")


def fig9_greedy_vs_exhaustive() -> None:
    """Fig 9: config-search efficiency at high modularity."""
    base = ipv4_like(2)
    for mod in (4, 8):
        stream = reinterpret_modularity(base, mod)
        rng = np.random.default_rng(4)
        s_items, s_freqs = stream.sample(0.02, rng)
        t0 = time.perf_counter()
        g = greedy_config(s_items, s_freqs, stream.schema, 4096, 4, KEY)
        t_g = time.perf_counter() - t0
        if mod <= 4:
            t0 = time.perf_counter()
            exhaustive_config(s_items, s_freqs, stream.schema, 4096, 4, KEY)
            t_ex = time.perf_counter() - t0
            extra = f"exhaustive_s={t_ex:.1f};ratio={t_ex / t_g:.1f}x"
        else:
            extra = (f"exhaustive=DNF(T({mod})={bell_number(mod)} partitions; "
                     "paper: >100h)")
        emit(f"fig9_mod{mod}", t_g * 1e6,
             f"greedy_s={t_g:.1f};candidates={g.n_candidates};{extra}")


def fig10_fcm_fmod() -> None:
    """Fig 10: generality -- MOD on top of FCM (paper regime: overload +
    tail queries, where composite indexing helps; see EXPERIMENTS SRepro)."""
    stream = twitter_like()
    h, w = 2048, 6
    rng = np.random.default_rng(5)
    s_items, s_freqs = stream.sample(0.03, rng)
    a, b = optimal_ranges_mod2(s_items, s_freqs, h)
    queries = stream.random_k_queries(500, rng)

    t0 = time.perf_counter()
    cm_err = sketch_error(sk.count_min_spec(stream.schema, h, w), stream, KEY,
                          queries)
    mod_err = sketch_error(sk.mod_sketch_spec(stream.schema, [(0,), (1,)],
                                              (a, b), w), stream, KEY, queries)
    fcm = FCM(fcm_spec(stream.schema, h, w, mg_k=512), KEY)
    fmod = FCM(fmod_spec(stream.schema, [(0,), (1,)], (a, b), w, mg_k=512), KEY)
    for s in range(0, len(stream.items), 1 << 15):
        fcm.update(stream.items[s:s + (1 << 15)], stream.freqs[s:s + (1 << 15)])
        fmod.update(stream.items[s:s + (1 << 15)], stream.freqs[s:s + (1 << 15)])
    from repro.streams import observed_error
    qi, qf = queries
    fcm_err = observed_error(fcm.query(qi), qf)
    fmod_err = observed_error(fmod.query(qi), qf)
    us = (time.perf_counter() - t0) * 1e6
    emit("fig10_fcm", us,
         f"count-min={cm_err:.4f};mod={mod_err:.4f};fcm={fcm_err:.4f};"
         f"fmod={fmod_err:.4f}")


def fig11_aggregates() -> None:
    """Fig 11: median vs min/max/average alpha aggregation."""
    stream = twitter_like()
    h, w = 4096, 5
    rng = np.random.default_rng(6)
    s_items, s_freqs = stream.sample(0.02, rng)
    queries = stream.top_k_queries(100)
    out = []
    t0 = time.perf_counter()
    for agg in ("median", "mean", "min", "max"):
        alpha = estimate_alpha(s_items, s_freqs, [0], [1], agg)
        a, b = split_range(h, 1.0 / alpha)
        err = sketch_error(sk.mod_sketch_spec(stream.schema, [(0,), (1,)],
                                              (a, b), w), stream, KEY, queries)
        out.append(f"{agg}={err:.4f}")
    us = (time.perf_counter() - t0) * 1e6
    emit("fig11_aggregates", us, ";".join(out))


def marginal_queries() -> None:
    """Beyond-figure: subspace queries (gMatrix/TCM capability the paper
    cites as composite hashing's motivation) -- O(x1,*) from b cells/row."""
    stream = ipv4_like(1)
    spec = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (256, 64), 5)
    state = sk.build_sketch(spec, KEY, stream.items, stream.freqs)
    srcs = np.unique(stream.items[:, 0])[:500].reshape(-1, 1)
    t0 = time.perf_counter()
    est = np.asarray(sk.query_marginal(spec, state, 0, jnp.asarray(srcs)))
    us = (time.perf_counter() - t0) * 1e6
    from repro.streams.stats import exact_marginals
    o1 = exact_marginals(stream.items, stream.freqs, [0])
    lut = {int(i): m for i, m in zip(stream.items[:, 0], o1)}
    true = np.array([lut[int(v)] for v in srcs[:, 0]])
    corr = float(np.corrcoef(est, true)[0, 1])
    over = bool((est >= true - 1e-6).all())
    emit("marginal_query_src", us, f"corr={corr:.3f};overestimate={over};"
         f"n=500;note=CM cannot answer without key enumeration")


ALL = [table1_bell, fig4_accuracy_vs_k, fig5_sample_size,
       fig6_param_search_time, fig7_modularity_4_8, fig8_throughput,
       fig9_greedy_vs_exhaustive, fig10_fcm_fmod, fig11_aggregates,
       marginal_queries]
