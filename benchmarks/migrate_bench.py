"""Online auto-tune + hot-migration benchmarks -> BENCH_MIGRATE.json.

Run via ``python -m benchmarks.run --only migrate``:

  * ``migrate/accuracy_retuned`` / ``migrate/accuracy_stale`` -- the
    headline accuracy pair: a SketchTopKEndpoint under the online
    AutoTuner (serving/autotune.py) streams a module-skew-flip workload
    (streams.dstream.skew_flip_batches); after the drift the tuner
    re-optimizes the per-group ranges from live stats and hot-migrates.
    Both rows score top-k ARE over the migrated endpoint's serving window
    against a STALE-spec twin fed exactly the same window -- isolating the
    spec effect.  The re-tuned ARE must be strictly lower; this pair is
    the artifact's reason to exist.
  * ``migrate/double_write_overhead`` -- ingest cost with an open
    double-write window vs without (the price of a migration in flight).
  * ``migrate/cutover`` -- wall time of the cutover ingest itself (state
    adoption is reference swapping; the fold dominates).

CPU/interpret numbers: orchestration + jnp scatter costs, not kernel
speed (docs/benchmarks.md, "interpret-mode caveat").
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import sketch as sk
from repro.core.hashing import KeySchema
from repro.serving.autotune import AutoTuner
from repro.serving.engine import SketchTopKEndpoint
from repro.streams import average_relative_error, skew_flip_batches

_DOMAINS = (1 << 12, 1 << 12)
_BATCHES = 16
_ROWS = 3_000
_H, _W = 1_024, 4


def _stale_spec(schema: KeySchema) -> sk.SketchSpec:
    # ranges tuned for a skewed module 0 / wide module 1; the stream
    # flips that halfway through
    return sk.mod_sketch_spec(schema, [(0,), (1,)],
                              (max(2, _H // 64), 64), _W)


def migrate_accuracy_drift() -> None:
    schema = KeySchema(domains=_DOMAINS)
    key = jax.random.PRNGKey(0)
    live = SketchTopKEndpoint(_stale_spec(schema), key)
    tuner = AutoTuner(live, jax.random.fold_in(key, 1),
                      retune_every=12_000, warmup=6_000,
                      min_improvement=0.9, sample_k=256, min_threshold=1,
                      search="ranges")
    batches = list(skew_flip_batches(_DOMAINS, _BATCHES, _ROWS, seed=0))

    window_start = 0
    t0 = time.perf_counter()
    for b, batch in enumerate(batches):
        live.ingest(batch.items, batch.freqs)
        d = tuner.step()
        if d is not None and d.migrated:
            window_start = b + 1       # successor absorbs from next block
    us = (time.perf_counter() - t0) * 1e6 / _BATCHES

    # stale twin + exact counts over the migrated endpoint's window
    frozen = SketchTopKEndpoint(_stale_spec(schema), key)
    exact: dict = {}
    for batch in batches[window_start:]:
        frozen.ingest(batch.items, batch.freqs)
        for it, f in zip(batch.items.tolist(), batch.freqs.tolist()):
            exact[tuple(it)] = exact.get(tuple(it), 0) + f
    top = sorted(exact.items(), key=lambda kv: -kv[1])[:32]
    q = np.array([k for k, _ in top], dtype=np.uint32)
    true = np.array([v for _, v in top], dtype=np.int64)

    def _are(ep):
        est = np.asarray(sk.query(ep.hspec.levels[-1], ep.state.states[-1],
                                  q)).astype(np.int64)
        return average_relative_error(true, est)

    n_mig = sum(d.migrated for d in tuner.decisions)
    emit("migrate/accuracy_retuned", us,
         f"are={_are(live):.4f};migrations={n_mig};"
         f"ranges={'x'.join(map(str, live.hspec.base.ranges))};"
         f"window_blocks={_BATCHES - window_start}")
    emit("migrate/accuracy_stale", us,
         f"are={_are(frozen):.4f};"
         f"ranges={'x'.join(map(str, frozen.hspec.base.ranges))};"
         f"window_blocks={_BATCHES - window_start}")


def migrate_double_write_overhead() -> None:
    schema = KeySchema(domains=_DOMAINS)
    key = jax.random.PRNGKey(0)
    spec = _stale_spec(schema)
    new = sk.mod_sketch_spec(schema, [(0,), (1,)], (64, 16), _W)
    blocks = list(skew_flip_batches(_DOMAINS, 8, _ROWS, seed=1))

    def _stream_through(migrating: bool) -> float:
        ep = SketchTopKEndpoint(spec, key)
        if migrating:
            ep.begin_migration(new, jax.random.fold_in(key, 2),
                               warmup=1 << 40)          # never cuts over
        # warm BOTH folds' jit caches (the successor compiles its own
        # spec's executables) so the ratio is steady-state double-write
        # cost, not compile time
        ep.ingest(blocks[0].items, blocks[0].freqs)
        ep.ingest(blocks[1].items, blocks[1].freqs)
        t0 = time.perf_counter()
        for b in blocks[2:]:
            ep.ingest(b.items, b.freqs)
        return (time.perf_counter() - t0) * 1e6 / (len(blocks) - 2)

    single = _stream_through(False)
    double = _stream_through(True)
    emit("migrate/double_write_overhead", double,
         f"single_us={single:.1f};ratio={double / max(single, 1e-9):.2f}")


def migrate_cutover_latency() -> None:
    schema = KeySchema(domains=_DOMAINS)
    key = jax.random.PRNGKey(0)
    spec = _stale_spec(schema)
    new = sk.mod_sketch_spec(schema, [(0,), (1,)], (64, 16), _W)
    blocks = list(skew_flip_batches(_DOMAINS, 4, _ROWS, seed=2))
    ep = SketchTopKEndpoint(spec, key)
    for b in blocks[:3]:
        ep.ingest(b.items, b.freqs)
    warm = int(blocks[3].freqs.sum())
    ep.begin_migration(new, jax.random.fold_in(key, 3), warmup=warm)
    t0 = time.perf_counter()
    ep.ingest(blocks[3].items, blocks[3].freqs)         # crosses warmup
    us = (time.perf_counter() - t0) * 1e6
    assert not ep.migrating
    emit("migrate/cutover", us, f"warmup_mass={warm}")


ALL = [migrate_accuracy_drift, migrate_double_write_overhead,
       migrate_cutover_latency]
