"""Warn-only diff of two BENCH_*.json artifacts (perf-trajectory CI step).

    python -m benchmarks.diff_bench OLD.json NEW.json [--threshold 1.30]
                                                      [--seed-baseline]

Compares rows by name and prints a ``::warning::`` line (GitHub Actions
annotation syntax; plain text elsewhere) for every benchmark whose
``us_per_call`` regressed by more than ``--threshold`` (default 1.30x) and
for rows that disappeared.  ALWAYS exits 0: CI timing boxes are noisy, so
the trajectory is recorded and surfaced, never enforced -- a sustained
regression shows up as the same warning on consecutive runs.

A missing, unreadable, or row-less OLD artifact is the first-run case,
not an error: the diff reports "no prior" and, with ``--seed-baseline``,
copies NEW into OLD's place so the very next run has a trajectory to
diff against even when the surrounding cache step failed to provide one
(a freshly added BENCH_*.json -- e.g. BENCH_MIGRATE.json -- starts its
trajectory this way).
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys


def _rows(path: str) -> dict:
    with open(path) as f:
        artifact = json.load(f)
    return {r["name"]: r for r in artifact.get("results", [])
            if r.get("us_per_call", -1) > 0}


def _seed_baseline(args) -> None:
    if not args.seed_baseline:
        return
    if not os.path.exists(args.new):
        return
    parent = os.path.dirname(os.path.abspath(args.old))
    os.makedirs(parent, exist_ok=True)
    shutil.copyfile(args.new, args.old)
    print(f"seeded baseline {args.old} from {args.new}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=1.30,
                    help="warn when new/old wall time exceeds this ratio")
    ap.add_argument("--seed-baseline", action="store_true",
                    help="when OLD is missing/empty/unparseable, copy NEW "
                         "into its place so the next run has a baseline")
    args = ap.parse_args()

    if not os.path.exists(args.old):
        print(f"no prior artifact at {args.old}; skipping diff (first run)")
        _seed_baseline(args)
        return
    try:
        old = _rows(args.old)
    except (OSError, ValueError, KeyError, TypeError) as e:
        print(f"could not parse prior artifact {args.old} ({e}); "
              "treating as no prior")
        _seed_baseline(args)
        return
    if not old:
        print(f"prior artifact {args.old} has no usable rows (empty "
              "trajectory); treating as no prior")
        _seed_baseline(args)
        return
    try:
        new = _rows(args.new)
    except (OSError, ValueError, KeyError, TypeError) as e:
        print(f"could not parse new artifact {args.new} ({e}); skipping diff")
        return

    regressions = improvements = 0
    for name, o in sorted(old.items()):
        n = new.get(name)
        if n is None:
            print(f"::warning::bench row disappeared: {name}")
            continue
        ratio = n["us_per_call"] / max(o["us_per_call"], 1e-9)
        if ratio > args.threshold:
            regressions += 1
            print(f"::warning::bench regression {name}: "
                  f"{o['us_per_call']:.1f}us -> {n['us_per_call']:.1f}us "
                  f"({ratio:.2f}x)")
        elif ratio < 1.0 / args.threshold:
            improvements += 1
            print(f"bench improvement {name}: {o['us_per_call']:.1f}us -> "
                  f"{n['us_per_call']:.1f}us ({ratio:.2f}x)")
    print(f"diffed {len(old)} baseline rows vs {len(new)} new rows: "
          f"{regressions} regression(s), {improvements} improvement(s)")
    # warn-only by contract: never fail the build on timing noise
    sys.exit(0)


if __name__ == "__main__":
    main()
