"""Warn-only diff of two BENCH_*.json artifacts (perf-trajectory CI step).

    python -m benchmarks.diff_bench OLD.json NEW.json [--threshold 1.30]

Compares rows by name and prints a ``::warning::`` line (GitHub Actions
annotation syntax; plain text elsewhere) for every benchmark whose
``us_per_call`` regressed by more than ``--threshold`` (default 1.30x) and
for rows that disappeared.  ALWAYS exits 0: CI timing boxes are noisy, so
the trajectory is recorded and surfaced, never enforced -- a sustained
regression shows up as the same warning on consecutive runs.

Missing/unreadable OLD file is not an error either (first run of a new
artifact has no baseline yet).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _rows(path: str) -> dict:
    with open(path) as f:
        artifact = json.load(f)
    return {r["name"]: r for r in artifact.get("results", [])
            if r.get("us_per_call", -1) > 0}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=1.30,
                    help="warn when new/old wall time exceeds this ratio")
    args = ap.parse_args()

    if not os.path.exists(args.old):
        print(f"no baseline at {args.old}; skipping diff (first run)")
        return
    try:
        old = _rows(args.old)
        new = _rows(args.new)
    except (OSError, ValueError, KeyError) as e:
        print(f"could not parse artifacts ({e}); skipping diff")
        return

    regressions = improvements = 0
    for name, o in sorted(old.items()):
        n = new.get(name)
        if n is None:
            print(f"::warning::bench row disappeared: {name}")
            continue
        ratio = n["us_per_call"] / max(o["us_per_call"], 1e-9)
        if ratio > args.threshold:
            regressions += 1
            print(f"::warning::bench regression {name}: "
                  f"{o['us_per_call']:.1f}us -> {n['us_per_call']:.1f}us "
                  f"({ratio:.2f}x)")
        elif ratio < 1.0 / args.threshold:
            improvements += 1
            print(f"bench improvement {name}: {o['us_per_call']:.1f}us -> "
                  f"{n['us_per_call']:.1f}us ({ratio:.2f}x)")
    print(f"diffed {len(old)} baseline rows vs {len(new)} new rows: "
          f"{regressions} regression(s), {improvements} improvement(s)")
    # warn-only by contract: never fail the build on timing noise
    sys.exit(0)


if __name__ == "__main__":
    main()
