"""Hierarchy ingest benchmark: fused single-launch cascade vs per-level
launches (PR-5 acceptance surface; archived as BENCH_HIERARCHY.json).

    PYTHONPATH=src python -m benchmarks.run --only hier_ingest \
        --json-out BENCH_HIERARCHY.json

Two comparisons, swept over hierarchy depth and stream block size:

  * ``hier_ingest/fused_pallas_*`` vs ``hier_ingest/perlevel_pallas_*`` --
    the ACCEPTANCE comparison: the fused single-launch kernel
    (kernels/hier_update.py, one pallas_call over the concatenated level
    tables, hash cached per row) against the per-level launch path (one
    sketch_update_pallas launch per level, re-hashing its prefix at every
    grid step).  The per-level row carries ``fused_speedup``; the
    criterion is >= 2x at depth >= 3.  On this container both run
    interpret=True, which prices each grid step's hash + one-hot work in
    the same (Python) currency as TPU grid steps price VPU + MXU work;
    re-run with interpret=False on TPU for hardware numbers.
  * ``hier_ingest/cascade_jnp_*`` vs ``hier_ingest/perlevel_jnp_*`` -- the
    compiled XLA ingest paths: the shared-family cascade (ONE hash pass +
    integer divisions + L scatter-adds in one jit'd call, tables donated)
    against the pre-PR-5 per-level path (L re-hash + scatter launches,
    core.hierarchy.update_reference).  On CPU XLA the serial scatter-adds
    dominate and both paths do L of them, so these rows sit near 1x --
    they exist to track the TPU trend (where the one-hot matmul update
    replaces the scatter and hashing/launches matter), not to carry the
    acceptance number.

Hash cost dominates the kernel rows by construction (2-chunk 32-bit
modules, small level tables) -- the telemetry-key regime the serving
endpoints ingest.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import hierarchy as hh
from repro.core import sketch as sk
from repro.core.hashing import KeySchema

_RANGES = {2: (256, 256), 3: (64, 64, 32), 4: (32, 32, 16, 8)}
_W = 4


def _hier(depth: int) -> hh.HierarchySpec:
    schema = KeySchema(domains=(1 << 32,) * depth)   # 2 chunks per module
    base = sk.mod_sketch_spec(schema, [(i,) for i in range(depth)],
                              _RANGES[depth], _W)
    return hh.HierarchySpec.from_spec(base)


def _stream(hspec: hh.HierarchySpec, b: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    items = np.stack(
        [rng.integers(0, d, b, dtype=np.uint64).astype(np.uint32)
         for d in hspec.base.schema.domains], axis=1)
    freqs = rng.integers(1, 100, b).astype(np.int32)
    return jnp.asarray(items), jnp.asarray(freqs)


@functools.partial(jax.jit, static_argnums=0)
def _perlevel_jit(hspec, state, items, freqs):
    # the pre-cascade ingest fold: every level re-hashes its prefix
    return hh.update_reference(hspec, state, items, freqs)


def _timed_median(fn, repeat: int = 7) -> float:
    """Median wall time in us (one warmup call first) -- medians keep the
    jnp rows stable against CPU scheduling noise."""
    fn()
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def hier_ingest_fused_vs_perlevel() -> None:
    key = jax.random.PRNGKey(0)
    for depth in (2, 3, 4):
        hspec = _hier(depth)
        for b in (4096, 16384):
            items, freqs = _stream(hspec, b, seed=depth)

            ref_state = hh.init_hierarchy(hspec, key)
            us_ref = _timed_median(lambda: jax.block_until_ready(
                _perlevel_jit(hspec, ref_state, items, freqs)
                .states[-1].table))
            emit(f"hier_ingest/perlevel_jnp_L{depth}_B{b}", us_ref,
                 f"items_per_s={b / (us_ref / 1e6):.3e};launches={depth}")

            holder = {"state": hh.init_hierarchy(hspec, key)}

            def cascade_step():
                # update_jit donates the level tables: thread the state
                holder["state"] = hh.update_jit(hspec, holder["state"],
                                                items, freqs)
                jax.block_until_ready(holder["state"].states[-1].table)

            us_cas = _timed_median(cascade_step)
            emit(f"hier_ingest/cascade_jnp_L{depth}_B{b}", us_cas,
                 f"items_per_s={b / (us_cas / 1e6):.3e};launches=1;"
                 f"speedup_vs_perlevel={us_ref / us_cas:.2f}x")


def hier_ingest_pallas_launches() -> None:
    """Interpret-mode Pallas rows: fused single launch vs one launch per
    level, same block.  Tracks the TPU comparison; CPU wall time is the
    Python interpreter, not the hardware."""
    from repro.kernels import KernelHierarchy, make_plan
    from repro.kernels.sketch_update import (
        padded_table_size,
        sketch_update_pallas,
    )

    depth, b, tile_h = 3, 512, 128
    hspec = _hier(depth)
    key = jax.random.PRNGKey(1)
    items, freqs = _stream(hspec, b, seed=7)
    np_items = np.asarray(items)

    kh = KernelHierarchy(hspec, key, tile_h=tile_h, block_b=b,
                         interpret=True)
    kh.update(np_items, np.asarray(freqs))      # warmup: trace + compile
    t0 = time.perf_counter()
    kh.update(np_items, np.asarray(freqs))
    us_fused = (time.perf_counter() - t0) * 1e6
    emit(f"hier_ingest/fused_pallas_L{depth}_B{b}", us_fused,
         f"items_per_s={b / (us_fused / 1e6):.3e};launches=1;"
         f"tiles={kh.hplan.n_tiles};interpret=True")

    # per-level: one sketch_update_pallas launch per level, same params
    state = kh.state()
    plans = [make_plan(s) for s in hspec.levels]

    def per_level_pass(tables):
        for lvl, (spec_l, plan_l) in enumerate(zip(hspec.levels, plans)):
            chunks = spec_l.schema.module_chunks(
                jnp.asarray(hspec.level_items(lvl, np_items)))
            p = state.states[lvl].params
            tables[lvl] = sketch_update_pallas(
                plan_l, tables[lvl], chunks, freqs, p.q, p.r,
                tile_h=tile_h, interpret=True)
        jax.block_until_ready(tables[-1])
        return tables

    tables = [jnp.zeros((s.width, padded_table_size(s.table_size, tile_h)),
                        jnp.int32) for s in hspec.levels]
    tables = per_level_pass(tables)             # warmup: trace + compile
    t0 = time.perf_counter()
    tables = per_level_pass(tables)
    us_per = (time.perf_counter() - t0) * 1e6
    emit(f"hier_ingest/perlevel_pallas_L{depth}_B{b}", us_per,
         f"items_per_s={b / (us_per / 1e6):.3e};launches={depth};"
         f"fused_speedup={us_per / us_fused:.2f}x;interpret=True")
    # parity while we are here: the per-level kernel tables must match the
    # fused kernel's level slices bit for bit
    for lvl, s in enumerate(hspec.levels):
        a = np.asarray(tables[lvl])[:, : s.table_size]
        b_ = np.asarray(state.states[lvl].table)
        assert (a == b_).all(), f"fused/per-level kernel mismatch at {lvl}"


ALL = [hier_ingest_fused_vs_perlevel, hier_ingest_pallas_launches]


if __name__ == "__main__":
    for fn in ALL:
        fn()
