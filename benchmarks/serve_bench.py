"""Async sketch-serving engine benchmarks -> BENCH_SERVE.json.

Run via ``python -m benchmarks.run --only serve``:

  * ``serve/mixed_*`` -- mixed-load throughput: one stream of ingest
    blocks with a top-k query every few blocks, served four ways.
    ``serialized`` is the pre-engine baseline (synchronous
    SketchTopKEndpoint: every query sees every ingested item);
    ``engine_stale0`` is the engine at ``max_staleness=0`` (same
    freshness contract, so it pays a snapshot refresh per query);
    ``engine_bounded`` allows a staleness budget so most queries reuse
    the snapshot; ``engine_unbounded`` only refreshes on explicit sync.
    The bounded/unbounded rows demonstrate the ingest/query overlap the
    engine exists for: pipelined ingest keeps streaming while queries
    answer from the snapshot, beating the serialized baseline
    (``speedup_vs_serialized`` in the derived fields).
  * ``serve/descent_*`` -- batched multi-request descent: Q concurrent
    top-k requests served by one submit/flush (one packed P x C x Q
    launch per level per round, core.hierarchy.batched_find_heavy_hitters)
    vs Q serial ``topk`` calls.  Same answers bit-for-bit
    (tests/test_serve_engine.py); the rows price the launch packing.

CPU/interpret numbers: orchestration + jnp gather costs, not kernel
speed (docs/benchmarks.md, "interpret-mode caveat").
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import sketch as sk
from repro.serving.sketch_engine import SketchServeEngine, SketchTopKEndpoint
from repro.streams import zipf_hh_workload

_RANGES, _W = (32, 32), 4
_BLOCK = 512
_QUERY_EVERY = 4          # one top-k query per this many ingested blocks
_TOPK = 16


def _workload(seed: int = 0):
    stream = zipf_hh_workload(n_src=1_000, n_tgt=2_000, n_edges=20_000,
                              n_occurrences=200_000, seed=seed).stream
    spec = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], _RANGES, _W)
    blocks = [(stream.items[s:s + _BLOCK], stream.freqs[s:s + _BLOCK])
              for s in range(0, stream.items.shape[0], _BLOCK)]
    return spec, blocks


def _run_mixed(ingest, query, blocks) -> float:
    """Wall time of the mixed load: ingest every block, query every
    _QUERY_EVERY blocks; returns seconds."""
    t0 = time.perf_counter()
    for b, (items, freqs) in enumerate(blocks):
        ingest(items, freqs)
        if (b + 1) % _QUERY_EVERY == 0:
            query(_TOPK)
    return time.perf_counter() - t0


def serve_mixed_load() -> None:
    spec, blocks = _workload()
    key = jax.random.PRNGKey(0)
    n_queries = len(blocks) // _QUERY_EVERY
    bound = sum(int(np.asarray(f).sum()) for _, f in blocks) // 4

    def timed_mixed(build):
        # run twice on fresh state, time the second: the first run compiles
        # every (block, candidate-count) shape so no config inherits or
        # pays compile costs depending on run order
        for i in range(2):
            ingest, query, drain = build()
            t = _run_mixed(ingest, query, blocks)
            drain()
        return t

    def serialized():
        ep = SketchTopKEndpoint(spec, key)
        return ep.ingest, ep.topk, lambda: None

    def engine(staleness):
        eng = SketchServeEngine(SketchTopKEndpoint(spec, key),
                                max_staleness=staleness)
        return eng.ingest, eng.topk, eng.drain

    dt_serial = timed_mixed(serialized)
    emit("serve/mixed_serialized", dt_serial * 1e6 / len(blocks),
         f"blocks={len(blocks)};queries={n_queries};block={_BLOCK};"
         f"k={_TOPK};speedup_vs_serialized=1.00")

    for label, staleness in (("stale0", 0), ("bounded", bound),
                             ("unbounded", None)):
        dt = timed_mixed(lambda: engine(staleness))
        emit(f"serve/mixed_engine_{label}", dt * 1e6 / len(blocks),
             f"blocks={len(blocks)};queries={n_queries};"
             f"max_staleness={staleness};"
             f"speedup_vs_serialized={dt_serial / dt:.2f}")


def serve_batched_descent() -> None:
    spec, blocks = _workload(seed=3)
    key = jax.random.PRNGKey(0)
    ep = SketchTopKEndpoint(spec, key)
    for items, freqs in blocks:
        ep.ingest(items, freqs)
    eng = SketchServeEngine(ep, max_staleness=None)
    eng.sync()

    for q in (1, 4, 16):
        ks = [_TOPK + 2 * i for i in range(q)]  # distinct request shapes

        def serial():
            return [eng.topk(k) for k in ks]

        def batched():
            for k in ks:
                eng.submit_topk(k)
            return eng.flush()

        serial(); batched()                     # warmup/compile
        t0 = time.perf_counter(); serial(); dt_s = time.perf_counter() - t0
        t0 = time.perf_counter(); batched(); dt_b = time.perf_counter() - t0
        emit(f"serve/descent_serial_q{q}", dt_s * 1e6 / q,
             f"q={q};k0={_TOPK};speedup=1.00")
        emit(f"serve/descent_batched_q{q}", dt_b * 1e6 / q,
             f"q={q};k0={_TOPK};speedup={dt_s / dt_b:.2f}")


ALL = [serve_mixed_load, serve_batched_descent]
