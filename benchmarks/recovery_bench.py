"""Durability-layer benchmarks -> BENCH_RECOVERY.json.

Run via ``python -m benchmarks.run --only recovery``:

  * ``recovery/snapshot`` -- wall cost of a durable snapshot (drain +
    state_dict + CRC'd write + WAL rotate/prune) as the sketch tables
    grow; the knob that prices the snapshot cadence.
  * ``recovery/replay`` -- crash-recovery wall time and replayed-block
    throughput as a function of snapshot cadence: cadence bounds how much
    WAL a recovery must re-fold, so this row pair is the
    recovery-time-vs-ingest-overhead trade made measurable.
  * ``recovery/wal_overhead`` -- steady-state ingest cost bare vs with a
    WAL (fsync off/on): what durability charges every block that never
    crashes.
  * ``recovery/remesh`` -- N->M shard re-meshing latency across
    1->2->4->8 (as the forced device count allows): sync + pool fold +
    table re-layout + jit-wrapper rebuild, the downtime of an elastic
    resize.

CPU/interpret numbers: orchestration + fsync costs dominate, not device
table speed (docs/benchmarks.md, "interpret-mode caveat").
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import sketch as sk
from repro.serving.recovery import DurableSketchEngine, recover
from repro.serving.sketch_engine import SketchServeEngine, SketchTopKEndpoint
from repro.streams import zipf_hh_workload

_KEY = jax.random.PRNGKey(0)
_BLOCK = 500


def _blocks(n_occurrences=40_000, n_edges=8_000, seed=3):
    stream = zipf_hh_workload(n_src=2_000, n_tgt=4_000, n_edges=n_edges,
                              n_occurrences=n_occurrences, seed=seed).stream
    return stream, [(stream.items[s:s + _BLOCK], stream.freqs[s:s + _BLOCK])
                    for s in range(0, stream.items.shape[0], _BLOCK)]


def recovery_snapshot_cost() -> None:
    stream, blocks = _blocks()
    for h in (256, 1024, 4096):
        spec = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (h, h), 4)
        with tempfile.TemporaryDirectory() as d:
            eng = DurableSketchEngine(
                SketchServeEngine(SketchTopKEndpoint(spec, _KEY)), d,
                fsync=False)
            for it, fr in blocks[:8]:
                eng.ingest(it, fr)
            eng.snapshot()                       # warm the write path
            t0 = time.perf_counter()
            reps = 5
            for _ in range(reps):
                eng.snapshot()
            dt = (time.perf_counter() - t0) / reps
            cells = sum(int(np.prod(st.table.shape))
                        for st in eng.backend.state.states)
            emit("recovery/snapshot", dt * 1e6,
                 f"h={h};table_cells={cells};keep=3")
            eng.close()


def recovery_replay_throughput() -> None:
    stream, blocks = _blocks()
    spec = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (1024, 1024), 4)
    for cadence in (None, 8, 2):
        with tempfile.TemporaryDirectory() as d:
            eng = DurableSketchEngine(
                SketchServeEngine(SketchTopKEndpoint(spec, _KEY)), d,
                snapshot_every=cadence, fsync=False)
            for it, fr in blocks:
                eng.ingest(it, fr)
            eng.close()
            t0 = time.perf_counter()
            eng2, rep = recover(d, lambda: SketchTopKEndpoint(spec, _KEY),
                                fsync=False)
            dt = time.perf_counter() - t0
            eng2.close()
            blk_s = rep.replayed_blocks / dt if dt > 0 else 0.0
            emit("recovery/replay", dt * 1e6,
                 f"cadence={cadence};replayed={rep.replayed_blocks};"
                 f"blocks_per_s={blk_s:.1f};"
                 f"restored_step={rep.restored_step}")


def recovery_wal_overhead() -> None:
    stream, blocks = _blocks()
    spec = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (1024, 1024), 4)

    def run(build):
        eng = build()
        for it, fr in blocks[:4]:                # warm-up + jit
            eng.ingest(it, fr)
        t0 = time.perf_counter()
        for it, fr in blocks[4:]:
            eng.ingest(it, fr)
        eng.drain()
        return (time.perf_counter() - t0) / max(1, len(blocks) - 4), eng

    bare_us, eng = run(lambda: SketchServeEngine(SketchTopKEndpoint(spec,
                                                                    _KEY)))
    emit("recovery/wal_overhead", bare_us * 1e6, "wal=off;fsync=-")
    for fsync in (False, True):
        d = tempfile.mkdtemp()
        try:
            dur_us, eng = run(lambda: DurableSketchEngine(
                SketchServeEngine(SketchTopKEndpoint(spec, _KEY)), d,
                fsync=fsync))
            eng.close()
            emit("recovery/wal_overhead", dur_us * 1e6,
                 f"wal=on;fsync={fsync};overhead_x={dur_us / bare_us:.2f}")
        finally:
            shutil.rmtree(d, ignore_errors=True)


def recovery_remesh_latency() -> None:
    n_dev = jax.device_count()
    if n_dev < 2:
        emit("recovery/remesh", 0.0, f"skipped=devices<2;devices={n_dev}")
        return
    from repro.serving.sharded_topk import ShardedTopKService

    stream, blocks = _blocks()
    spec = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (512, 512), 4)
    ladder = [n for n in (1, 2, 4, 8) if n <= n_dev]
    for src, dst in zip(ladder, ladder[1:]):
        svc = ShardedTopKService(spec, _KEY, jax.make_mesh((src,), ("data",)),
                                 sync_every=4)
        for it, fr in blocks[:12]:
            svc.ingest(it, fr)
        dst_mesh = jax.make_mesh((dst,), ("data",))
        t0 = time.perf_counter()
        svc.remesh(dst_mesh)
        jax.block_until_ready([st.table for st in svc.merged.states])
        dt = time.perf_counter() - t0
        emit("recovery/remesh", dt * 1e6,
             f"src={src};dst={dst};devices={n_dev}")
        # and back down: shrink is the failure-response direction
        src_mesh = jax.make_mesh((src,), ("data",))
        t0 = time.perf_counter()
        svc.remesh(src_mesh)
        jax.block_until_ready([st.table for st in svc.merged.states])
        dt = time.perf_counter() - t0
        emit("recovery/remesh", dt * 1e6,
             f"src={dst};dst={src};devices={n_dev}")


ALL = [recovery_snapshot_cost, recovery_replay_throughput,
       recovery_wal_overhead, recovery_remesh_latency]
